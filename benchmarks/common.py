"""Shared helpers for the paper-reproduction benchmarks (Figures 6/7/8,
Table 2, Figure 15).  Each benchmark prints CSV rows:

    benchmark,variant,task,metric,value

and returns the rows so `benchmarks.run` can aggregate them."""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.api import (CostModel, LatencyRecorder,  # noqa: F401
                            Metrics)
#   LatencyRecorder: the shared percentile/latency recorder (also used by
#   the serving scheduler and serve_bench) — numpy-only, so importing it
#   here keeps the simulator benchmarks JAX-free
from repro.core.baselines import (NuPSStatic, SelectiveReplicationSSP,
                                  StaticFullReplication, StaticPartitioning)
from repro.core.manager import AdaPM
from repro.core.simulator import (SimConfig, Workload, simulate,
                                  single_node_epoch_time)
from repro.data.workloads import make_workload

TASKS = ("KGE", "WV", "MF", "CTR", "GNN")

# six NuPS configurations, mirroring the paper's quasi-random search over
# (hot-set size, relocation offset) (§D)
NUPS_CONFIGS = [
    (0.0005, 8), (0.002, 32), (0.01, 64),
    (0.05, 128), (0.002, 512), (0.01, 16),
]


def default_cost() -> CostModel:
    return CostModel()


def make_policy(name: str, n_nodes: int, cost: CostModel,
                wl: Workload, **kw):
    if name == "adapm":
        return AdaPM(n_nodes, cost, **kw)
    if name == "adapm_norel":
        return AdaPM(n_nodes, cost, relocation=False, **kw)
    if name == "adapm_norep":
        return AdaPM(n_nodes, cost, replication=False, **kw)
    if name == "adapm_immediate":
        return AdaPM(n_nodes, cost, immediate_action=True, **kw)
    if name == "full_replication":
        return StaticFullReplication(n_nodes, cost, wl.n_keys)
    if name == "static_partitioning":
        return StaticPartitioning(n_nodes, cost)
    if name == "ssp":
        return SelectiveReplicationSSP(n_nodes, cost,
                                       staleness_bound=kw.get("bound", 20))
    if name == "essp":
        return SelectiveReplicationSSP(n_nodes, cost, staleness_bound=None)
    if name.startswith("nups"):
        idx = int(name.split("_")[1])
        hot_frac, off = NUPS_CONFIGS[idx]
        return NuPSStatic(n_nodes, cost, wl.n_keys, wl.hot_keys(hot_frac),
                          reloc_offset=off)
    raise KeyError(name)


def run_one(policy_name: str, task: str, n_nodes: int = 8, wpn: int = 4,
            scale: float = 1.0, signal_offset: int = 100,
            cost: Optional[CostModel] = None, n_keys: Optional[int] = None,
            **kw) -> Metrics:
    cost = cost or default_cost()
    wl = make_workload(task, n_nodes=n_nodes, wpn=wpn, scale=scale,
                       n_keys=n_keys)
    pol = make_policy(policy_name, n_nodes, cost, wl, **kw)
    return simulate(pol, wl, SimConfig(signal_offset=signal_offset))


def speedup_vs_single_node(task: str, metrics: Metrics, n_nodes: int = 8,
                           wpn: int = 4, scale: float = 1.0,
                           cost: Optional[CostModel] = None,
                           n_keys: Optional[int] = None) -> float:
    cost = cost or default_cost()
    wl = make_workload(task, n_nodes=n_nodes, wpn=wpn, scale=scale,
                       n_keys=n_keys)
    t1 = single_node_epoch_time(wl, cost)
    return t1 / max(metrics.epoch_time, 1e-12)


def emit(rows: List[str], benchmark: str, variant: str, task: str,
         metric: str, value) -> None:
    row = f"{benchmark},{variant},{task},{metric},{value}"
    print(row)
    rows.append(row)


def time_fn(fn: Callable, *, iters: int = 5, warmup: int = 1,
            block: Optional[Callable] = None) -> float:
    """Mean microseconds per call of ``fn()`` over ``iters`` timed calls
    after ``warmup`` untimed ones (compile/caches).  ``block`` is applied
    to the last result before stopping the clock (pass
    ``jax.block_until_ready`` for async backends).  Replaces the ad-hoc
    timing loops that used to live in each benchmark module."""
    out = None
    for _ in range(warmup):
        out = fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    if block is not None:
        block(out)
    return (time.perf_counter() - t0) / iters * 1e6


def paired_pooled_ratio(run_base: Callable[[], List[float]],
                        run_test: Callable[[], List[float]],
                        *, reps: int = 6) -> Dict[str, float]:
    """The PR-8 paired-arm estimator, shared (DESIGN.md §14/§15): both
    arms run back-to-back per rep in alternating order (cancels slow
    machine drift), every run's per-round/per-iter samples are POOLED
    per arm, and the verdict is the ratio of pooled medians — per-run
    aggregates on a small shared container have a multi-percent noise
    floor and cannot resolve single-digit effects; pooling
    ``reps x rounds`` samples tightens the median substantially.

    Residual session noise is measured inline: the *base* arm's runs
    split into two interleaved halves whose median ratio is an A/A
    measurement — a real regression moves A/B but not A/A, so callers
    discount their tolerance by ``drift``.

    Returns ``{"ratio": median(test)/median(base), "drift": A/A >= 1,
    "median_base", "median_test", "samples_per_arm"}``."""
    test_pool: List[float] = []
    base_halves: tuple = ([], [])
    for i in range(reps):
        if i % 2 == 0:
            test_pool += list(run_test())
            base = list(run_base())
        else:
            base = list(run_base())
            test_pool += list(run_test())
        base_halves[i % 2].extend(base)
    base_pool = base_halves[0] + base_halves[1]
    ratio = float(np.median(test_pool) / np.median(base_pool))
    aa = (float(np.median(base_halves[0]) / np.median(base_halves[1]))
          if base_halves[0] and base_halves[1] else 1.0)
    return {"ratio": ratio, "drift": float(max(aa, 1.0 / aa)),
            "median_base": float(np.median(base_pool)),
            "median_test": float(np.median(test_pool)),
            "samples_per_arm": min(len(base_pool), len(test_pool))}


def paired_guard(label: str, run_base: Callable[[], List[float]],
                 run_test: Callable[[], List[float]], *, tol: float,
                 reps: int = 6, best_of: int = 2) -> Dict[str, float]:
    """CI regression guard over `paired_pooled_ratio`: the test arm's
    pooled-median latency may exceed the base arm's by at most ``tol``
    (a ratio, e.g. 1.15) discounted by the measured A/A drift.  Samples
    are latencies — lower is better.  ``best_of`` full re-measurements
    ride out co-tenant bursts before the guard fails the build
    (`SystemExit`, the benches' guard convention)."""
    res = paired_pooled_ratio(run_base, run_test, reps=reps)
    for _ in range(best_of - 1):
        if res["ratio"] <= tol * res["drift"]:
            break
        res = paired_pooled_ratio(run_base, run_test, reps=reps)
    bound = tol * res["drift"]
    if res["ratio"] > bound:
        raise SystemExit(
            f"{label}: paired pooled-median regression "
            f"{res['ratio']:.4f}x > {bound:.4f}x (budget {tol:.2f}x * "
            f"A/A drift {res['drift']:.4f}x, "
            f"{res['samples_per_arm']} samples/arm)")
    print(f"{label} ok: paired pooled-median ratio {res['ratio']:.4f}x "
          f"(bound {bound:.4f}x = budget {tol:.2f}x * A/A drift "
          f"{res['drift']:.4f}x)")
    return res
