"""Shared helpers for the paper-reproduction benchmarks (Figures 6/7/8,
Table 2, Figure 15).  Each benchmark prints CSV rows:

    benchmark,variant,task,metric,value

and returns the rows so `benchmarks.run` can aggregate them."""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

from repro.core.api import (CostModel, LatencyRecorder,  # noqa: F401
                            Metrics)
#   LatencyRecorder: the shared percentile/latency recorder (also used by
#   the serving scheduler and serve_bench) — numpy-only, so importing it
#   here keeps the simulator benchmarks JAX-free
from repro.core.baselines import (NuPSStatic, SelectiveReplicationSSP,
                                  StaticFullReplication, StaticPartitioning)
from repro.core.manager import AdaPM
from repro.core.simulator import (SimConfig, Workload, simulate,
                                  single_node_epoch_time)
from repro.data.workloads import make_workload

TASKS = ("KGE", "WV", "MF", "CTR", "GNN")

# six NuPS configurations, mirroring the paper's quasi-random search over
# (hot-set size, relocation offset) (§D)
NUPS_CONFIGS = [
    (0.0005, 8), (0.002, 32), (0.01, 64),
    (0.05, 128), (0.002, 512), (0.01, 16),
]


def default_cost() -> CostModel:
    return CostModel()


def make_policy(name: str, n_nodes: int, cost: CostModel,
                wl: Workload, **kw):
    if name == "adapm":
        return AdaPM(n_nodes, cost, **kw)
    if name == "adapm_norel":
        return AdaPM(n_nodes, cost, relocation=False, **kw)
    if name == "adapm_norep":
        return AdaPM(n_nodes, cost, replication=False, **kw)
    if name == "adapm_immediate":
        return AdaPM(n_nodes, cost, immediate_action=True, **kw)
    if name == "full_replication":
        return StaticFullReplication(n_nodes, cost, wl.n_keys)
    if name == "static_partitioning":
        return StaticPartitioning(n_nodes, cost)
    if name == "ssp":
        return SelectiveReplicationSSP(n_nodes, cost,
                                       staleness_bound=kw.get("bound", 20))
    if name == "essp":
        return SelectiveReplicationSSP(n_nodes, cost, staleness_bound=None)
    if name.startswith("nups"):
        idx = int(name.split("_")[1])
        hot_frac, off = NUPS_CONFIGS[idx]
        return NuPSStatic(n_nodes, cost, wl.n_keys, wl.hot_keys(hot_frac),
                          reloc_offset=off)
    raise KeyError(name)


def run_one(policy_name: str, task: str, n_nodes: int = 8, wpn: int = 4,
            scale: float = 1.0, signal_offset: int = 100,
            cost: Optional[CostModel] = None, n_keys: Optional[int] = None,
            **kw) -> Metrics:
    cost = cost or default_cost()
    wl = make_workload(task, n_nodes=n_nodes, wpn=wpn, scale=scale,
                       n_keys=n_keys)
    pol = make_policy(policy_name, n_nodes, cost, wl, **kw)
    return simulate(pol, wl, SimConfig(signal_offset=signal_offset))


def speedup_vs_single_node(task: str, metrics: Metrics, n_nodes: int = 8,
                           wpn: int = 4, scale: float = 1.0,
                           cost: Optional[CostModel] = None,
                           n_keys: Optional[int] = None) -> float:
    cost = cost or default_cost()
    wl = make_workload(task, n_nodes=n_nodes, wpn=wpn, scale=scale,
                       n_keys=n_keys)
    t1 = single_node_epoch_time(wl, cost)
    return t1 / max(metrics.epoch_time, 1e-12)


def emit(rows: List[str], benchmark: str, variant: str, task: str,
         metric: str, value) -> None:
    row = f"{benchmark},{variant},{task},{metric},{value}"
    print(row)
    rows.append(row)


def time_fn(fn: Callable, *, iters: int = 5, warmup: int = 1,
            block: Optional[Callable] = None) -> float:
    """Mean microseconds per call of ``fn()`` over ``iters`` timed calls
    after ``warmup`` untimed ones (compile/caches).  ``block`` is applied
    to the last result before stopping the clock (pass
    ``jax.block_until_ready`` for async backends).  Replaces the ad-hoc
    timing loops that used to live in each benchmark module."""
    out = None
    for _ in range(warmup):
        out = fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    if block is not None:
        block(out)
    return (time.perf_counter() - t0) / iters * 1e6
