"""Figure 7: scalability of AdaPM vs NuPS on 2/4/8/16 nodes (KGE, WV, MF).

Claims validated: near-linear raw speedups for AdaPM; AdaPM's remote-access
share stays ~0 while NuPS's grows with the node count (relocation
conflicts, §5.7)."""

from __future__ import annotations

from typing import List

from .common import default_cost, emit, run_one, speedup_vs_single_node

NODES = (2, 4, 8, 16)
TASKS3 = ("KGE", "WV", "MF")


def run(scale: float = 0.35, wpn: int = 4, scale_keys: int = 0) -> List[str]:
    """Paper node-scaling sweep.  With ``scale_keys`` > 0, an additional
    engine-scale sweep runs the synthetic ZIPF task at that many keys across
    the same node counts (the vectorized intent engine makes key counts far
    beyond the per-key-dict seed feasible)."""
    rows: List[str] = []
    for task in TASKS3:
        for n in NODES:
            for variant in ("adapm", "nups_2"):
                m = run_one(variant, task, n_nodes=n, wpn=wpn, scale=scale)
                sp = speedup_vs_single_node(task, m, n_nodes=n, wpn=wpn,
                                            scale=scale)
                emit(rows, "fig7", variant, task, f"speedup_n{n}",
                     round(sp, 2))
                emit(rows, "fig7", variant, task, f"remote_frac_n{n}",
                     round(m.remote_fraction, 5))
    if scale_keys:
        for n in NODES:
            for variant in ("adapm", "static_partitioning"):
                m = run_one(variant, "ZIPF", n_nodes=n, wpn=wpn,
                            scale=scale, n_keys=scale_keys)
                sp = speedup_vs_single_node("ZIPF", m, n_nodes=n, wpn=wpn,
                                            scale=scale, n_keys=scale_keys)
                emit(rows, "fig7", variant, f"ZIPF{scale_keys}",
                     f"speedup_n{n}", round(sp, 2))
                emit(rows, "fig7", variant, f"ZIPF{scale_keys}",
                     f"remote_frac_n{n}", round(m.remote_fraction, 5))
    return rows


if __name__ == "__main__":
    run()
