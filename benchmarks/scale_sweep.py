"""Key-count scale sweep over the vectorized intent engine.

Runs skewed Zipf streams (`data.workloads.zipf_workload`) at
keys in {1e4, 1e5, 1e6} under AdaPM and static partitioning and records
simulator wall-clock next to the simulated metrics — the per-key-dict seed
could not finish the 1e6-key row at all.  Results are written to
``BENCH_scale.json`` at the repo root so later PRs have a perf trajectory.
"""

from __future__ import annotations

import json
import os
import time
from typing import List

from repro.core.simulator import SimConfig, simulate
from repro.data.workloads import zipf_workload

from .common import default_cost, emit, make_policy

KEY_COUNTS = (10_000, 100_000, 1_000_000)
VARIANTS = ("adapm", "static_partitioning")

_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                    "BENCH_scale.json")


def run(quick: bool = False, n_nodes: int = 4, wpn: int = 2,
        n_batches: int = 100, batch_size: int = 64) -> List[str]:
    rows: List[str] = []
    results = []
    key_counts = KEY_COUNTS[:2] if quick else KEY_COUNTS
    for n_keys in key_counts:
        t_wl = time.perf_counter()
        wl = zipf_workload(n_nodes=n_nodes, wpn=wpn, n_batches=n_batches,
                           n_keys=n_keys, batch_size=batch_size)
        gen_s = time.perf_counter() - t_wl
        for variant in VARIANTS:
            cost = default_cost()
            pol = make_policy(variant, n_nodes, cost, wl)
            t0 = time.perf_counter()
            m = simulate(pol, wl, SimConfig(signal_offset=100))
            wall = time.perf_counter() - t0
            emit(rows, "scale_sweep", variant, f"ZIPF{n_keys}",
                 "sim_wall_clock_s", round(wall, 3))
            emit(rows, "scale_sweep", variant, f"ZIPF{n_keys}",
                 "epoch_time_s", round(m.epoch_time, 4))
            emit(rows, "scale_sweep", variant, f"ZIPF{n_keys}",
                 "remote_frac", round(m.remote_fraction, 5))
            results.append({
                "n_keys": n_keys,
                "variant": pol.name,
                "workload_gen_s": round(gen_s, 3),
                "sim_wall_clock_s": round(wall, 3),
                **m.as_dict(),
            })
    if not quick:
        # --quick caps the sweep at 1e5 keys; writing that subset would
        # clobber the 1e6-key rows the perf trajectory tracks
        with open(_OUT, "w") as f:
            json.dump({"n_nodes": n_nodes, "wpn": wpn,
                       "n_batches": n_batches, "batch_size": batch_size,
                       "results": results}, f, indent=1)
        print(f"wrote {os.path.normpath(_OUT)}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="cap the sweep at 1e5 keys")
    run(quick=ap.parse_args().quick)
